"""Elastic-budget serving demo (paper Fig. 5 scenario).

A co-running application grabs memory mid-flight; the RAP server observes
the shrinking budget per request and prunes deeper on the fly, then relaxes
back to (nearly) the dense model when pressure clears — the "best of both
worlds" behaviour of §1.

  PYTHONPATH=src python examples/serve_elastic_budget.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.llama2_7b import RAP_SUBJECT
from repro.core import dqn, env as env_lib, memory
from repro.core.controller import RAPController
from repro.core.policy import RLPolicy
from repro.data import SyntheticCorpus, batch_iterator
from repro.models import registry
from repro.optim import adamw
from repro.runtime import RAPServer, Trainer, TrainerConfig


def main():
    cfg = RAP_SUBJECT.replace(n_layers=6)
    model = registry.build(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    trainer = Trainer(model, adamw.AdamWConfig(lr=1e-3, total_steps=60),
                      TrainerConfig(total_steps=60, log_every=60,
                                    remat=False))
    print("training the served model (60 steps)...")
    trainer.run(batch_iterator(corpus, 8, 128))
    params = trainer.params

    calib = {k: jnp.asarray(v) for k, v in corpus.batch(4, 128,
                                                        split="calib").items()}
    mm = memory.build_memory_model(cfg)
    e = env_lib.PruneEnv(model, params, calib, mm, chunk=16)

    def sampler(rng):
        bs, sql = int(rng.integers(1, 16)), int(rng.integers(256, 4096))
        return bs, sql, float(rng.uniform(0.55, 0.95)) * mm.dense_peak(bs, sql)

    print("training the RAP controller (10 episodes)...")
    tr = dqn.train(lambda: e, episodes=10, request_sampler=sampler)
    ctl = RAPController(model, params, calib, mm, tr.q_params, chunk=16)
    policy = RLPolicy(ctl)
    server = RAPServer(model, params, policy, mode="structural",
                       max_new_tokens=8)

    # memory pressure trace: healthy → interference spike → recovery
    trace = [0.95, 0.9, 0.62, 0.55, 0.58, 0.85, 0.95]
    rng = np.random.default_rng(0)
    bs, sql = 4, 512
    print(f"\nserving {len(trace)} requests (bs={bs}, seq={sql}) under a "
          "memory-pressure trace:")
    for t, frac in enumerate(trace):
        prompt = corpus.sample_tokens(rng, bs, sql)
        budget = frac * mm.dense_peak(bs, sql + 8)
        r = server.serve(prompt, budget)
        bar = "#" * int(30 * frac)
        print(f"  t={t}: avail {frac:4.2f} {bar:<30s} kept "
              f"{int(r.mask.sum()):2d}/{len(r.mask)} blocks  "
              f"peak/budget {r.peak_bytes/budget:4.2f}  fits={r.fits}  "
              f"{'compile' if r.compiled_new else 'cached'}")
    print("\nexecutable buckets compiled:", server.stats())

    # ---- phase 2: the same contention made REAL — a burst of concurrent
    # requests competing for one shared KV pool through the engine
    # (DESIGN.md §10). Admission control queues what the pool cannot hold;
    # the controller prunes deeper as the pool fills.
    from repro.core import masks
    from repro.runtime import EngineConfig, EngineRequest, RAPEngine

    full = masks.full_mask(cfg.n_layers)
    max_total = 256 + 8
    pool_budget = (mm.param_bytes(full)
                   + 2.0 * mm.state_bytes(full, 1, max_total))
    engine = RAPEngine(model, params, policy, EngineConfig(
        mode="structural", max_new_tokens=8, max_active=4,
        max_len=max_total, budget_bytes=pool_budget))
    burst = [EngineRequest(rid=f"burst{i}",
                           prompt=corpus.sample_tokens(rng, 1, 256),
                           arrival_t=0.0)
             for i in range(8)]
    print(f"\nburst: 8 concurrent requests into a shared pool sized for "
          f"~2 dense requests ({pool_budget/1e6:.1f}MB total budget)")
    rep = engine.run(burst)
    for r in rep.results:
        print(f"  {r.rid}: kept {int(r.mask.sum()):2d}/{len(r.mask)}  "
              f"queued {r.queue_delay_s*1e3:5.0f}ms  fits={r.fits}")
    print(f"engine: {rep.tokens_per_s:.1f} tok/s, pool peak "
          f"{rep.pool['peak_reserved_bytes']/1e6:.2f}MB of "
          f"{rep.pool['capacity_bytes']/1e6:.2f}MB "
          f"(never exceeded), frag {rep.pool['fragmentation']:.2f}")


if __name__ == "__main__":
    main()
