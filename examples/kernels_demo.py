"""Pallas kernel walkthrough: run each TPU kernel (interpret mode on CPU)
against its oracle and print max deviations + the tiling it used.

  PYTHONPATH=src python examples/kernels_demo.py
"""
import numpy as np
import jax.numpy as jnp

from repro.kernels import ops, ref

rng = np.random.default_rng(0)
r = lambda *s: jnp.asarray(rng.standard_normal(s).astype(np.float32) * 0.5)


def show(name, got, want, tiling):
    d = float(np.abs(np.asarray(got) - np.asarray(want)).max())
    print(f"{name:18s} max|Δ| = {d:.2e}   tiling: {tiling}")


def main():
    q, k, v = r(2, 256, 8, 64), r(2, 256, 2, 64), r(2, 256, 2, 64)
    show("flash_attention",
         ops.flash_attention(q, k, v, block_q=128, block_k=128),
         ref.attention_ref(q, k, v),
         "grid (B,H,nq,nk), q-block 128×64, kv streams through VMEM")

    q1 = r(2, 1, 8, 64)
    kc, vc = r(2, 1024, 2, 64), r(2, 1024, 2, 64)
    valid = jnp.arange(1024) < 700
    show("decode_attention",
         ops.decode_attention(q1, kc, vc, valid, block_k=256),
         ref.decode_attention_ref(q1, kc, vc, valid),
         "grid (B,K,nk), GQA group on sublanes, split-KV carry")

    h = r(512, 1024)
    show("fused_glu", ops.fused_glu(h, "swiglu"),
         ref.glu_ref(h, "swiglu"),
         "grid (T/256, F/512), gate|up halves via index_map offsets")

    xh, la = r(1, 512, 4, 32), -jnp.abs(r(1, 512, 4)) * 0.1
    Bm, Cm = r(1, 512, 64), r(1, 512, 64)
    y, fin = ops.ssd(xh, la, Bm, Cm, chunk=128)
    yr, fr = ref.ssd_ref(xh, la, Bm, Cm)
    show("ssd (y)", y, yr, "grid (B,H,chunks), [P,N] state carry in VMEM")
    show("ssd (state)", fin, fr, "  chunk-local quadratic on MXU")

    a = jnp.exp(-jnp.abs(r(2, 512, 256)))
    b = r(2, 512, 256)
    show("rglru", ops.rglru(a, b, block_t=128, block_w=128),
         ref.rglru_ref(a, b),
         "grid (B,W/bw,T/bt), assoc-scan per block + carry stitch")


if __name__ == "__main__":
    main()
