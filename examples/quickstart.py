"""Quickstart: train a small LM, score its blocks with GSI, make one
runtime-adaptive pruning decision, and run the pruned model.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.llama2_7b import RAP_SUBJECT
from repro.core import dqn, env as env_lib, gsi, masks, memory
from repro.core.controller import RAPController
from repro.data import SyntheticCorpus, batch_iterator
from repro.models import registry
from repro.optim import adamw
from repro.runtime import Trainer, TrainerConfig


def main():
    # 1. a small llama-family model + synthetic corpus
    cfg = RAP_SUBJECT.replace(n_layers=6)
    model = registry.build(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)

    # 2. train briefly
    trainer = Trainer(model, adamw.AdamWConfig(lr=1e-3, total_steps=60),
                      TrainerConfig(total_steps=60, log_every=20,
                                    remat=False),
                      on_log=lambda s, m: print(
                          f"  step {s}: loss {m['loss']:.3f}"))
    print("training 60 steps...")
    trainer.run(batch_iterator(corpus, 8, 128))
    params = trainer.params

    # 3. GSI block importance (Algorithm 1)
    calib = {k: jnp.asarray(v) for k, v in corpus.batch(4, 128,
                                                        split="calib").items()}
    res = gsi.gsi_rank(model, params, calib, max_removals=4, chunk=16)
    print(f"GSI removal order (least-important first): {res.order}")

    # 4. train the RL controller (Algorithm 2) and decide (Algorithm 3)
    mm = memory.build_memory_model(cfg)
    e = env_lib.PruneEnv(model, params, calib, mm, chunk=16)

    def sampler(rng):
        bs, sql = int(rng.integers(1, 16)), int(rng.integers(256, 4096))
        return bs, sql, float(rng.uniform(0.6, 0.9)) * mm.dense_peak(bs, sql)

    tr = dqn.train(lambda: e, episodes=8, request_sampler=sampler)
    ctl = RAPController(model, params, calib, mm, tr.q_params, chunk=16)

    bs, sql = 8, 2048
    budget = 0.7 * mm.dense_peak(bs, sql)
    d = ctl.decide(bs, sql, budget)
    print(f"request (bs={bs}, seq={sql}) at 70% budget → keep "
          f"{int(d.mask.sum())}/{len(d.mask)} blocks, "
          f"peak {d.peak_bytes/1e6:.1f}MB ≤ {budget/1e6:.1f}MB: {d.fits}")

    # 5. run the structurally pruned model
    small, layout = masks.compact_params(params, cfg, d.mask)
    from repro.models import decoder
    logits, _ = decoder.forward(small, cfg, calib["tokens"], layout=layout)
    print(f"pruned forward OK: logits {logits.shape}, "
          f"finite={bool(np.all(np.isfinite(np.asarray(logits))))}")


if __name__ == "__main__":
    main()
